"""NumPy oracle for the challenge queries — the "single-core Pandas" role.

The paper benchmarks cuDF (GPU) against the identical code running on
single-core Pandas.  Pandas is not available in this environment, so this
module is the CPU reference: a straightforward, sequential NumPy
implementation of every Table III query with *dynamic* shapes.  It is the
ground truth for all correctness tests and the denominator of the Fig. 1
speedup benchmark.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

__all__ = [
    "ref_traffic_matrix",
    "ref_run_all_queries",
    "ref_anonymize_check",
    "ref_isin",
    "ref_semi_join",
    "ref_top_links",
    "ref_windowed_histogram",
    "ref_window_ip_overlap",
]


def _weights(src: np.ndarray, n_packets: Optional[np.ndarray]) -> np.ndarray:
    return np.ones(len(src), np.int64) if n_packets is None else np.asarray(n_packets, np.int64)


def ref_traffic_matrix(src, dst, n_packets=None):
    """A_t as (src, dst, packets) arrays, lexicographically sorted."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    w = _weights(src, n_packets)
    order = np.lexsort((dst, src))
    s, d, w = src[order], dst[order], w[order]
    first = np.ones(len(s), bool)
    first[1:] = (s[1:] != s[:-1]) | (d[1:] != d[:-1])
    seg = np.cumsum(first) - 1
    packets = np.zeros(int(seg[-1]) + 1 if len(seg) else 0, np.int64)
    np.add.at(packets, seg, w)
    return s[first], d[first], packets


def ref_run_all_queries(src, dst, n_packets=None) -> Dict[str, int]:
    """All scalar challenge statistics (paper Table III), dynamically shaped."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    w = _weights(src, n_packets)
    ls, ld, lp = ref_traffic_matrix(src, dst, n_packets)

    def _maxcount(x) -> int:
        if len(x) == 0:
            return 0
        return int(np.unique(x, return_counts=True)[1].max())

    def _max_groupsum(keys, vals) -> int:
        if len(keys) == 0:
            return 0
        _, inv = np.unique(keys, return_inverse=True)
        sums = np.zeros(inv.max() + 1, np.int64)
        np.add.at(sums, inv, vals)
        return int(sums.max())

    return {
        "valid_packets": int(w.sum()),
        "unique_links": int(len(ls)),
        "max_link_packets": int(lp.max()) if len(lp) else 0,
        "n_unique_sources": int(len(np.unique(src))),
        "n_unique_destinations": int(len(np.unique(dst))),
        "n_unique_ips": int(len(np.unique(np.concatenate([src, dst])))),
        "max_source_packets": _max_groupsum(src, w),
        "max_source_fanout": _maxcount(ls),
        "max_destination_packets": _max_groupsum(dst, w),
        "max_destination_fanin": _maxcount(ld),
    }


def ref_isin(x, values) -> np.ndarray:
    """Oracle for ops.isin: plain ``np.isin``."""
    return np.isin(np.asarray(x), np.asarray(values))


def ref_semi_join(left_cols, right_cols) -> np.ndarray:
    """Oracle for ops.semi_join: tuple-set membership of left rows in right."""
    right = set(zip(*(np.asarray(c).tolist() for c in right_cols)))
    return np.array(
        [row in right for row in zip(*(np.asarray(c).tolist() for c in left_cols))],
        bool,
    )


def ref_top_links(src, dst, k, n_packets=None):
    """Oracle for queries.top_links: k heaviest links, ties by (src, dst) asc."""
    ls, ld, lp = ref_traffic_matrix(src, dst, n_packets)
    order = np.lexsort((ld, ls, -lp))[:k]
    return ls[order], ld[order], lp[order]


def ref_windowed_histogram(win, ids, n_windows, num_bins, weights=None) -> np.ndarray:
    """Oracle for kernels.ops.windowed_histogram: 2-D bincount."""
    win = np.asarray(win)
    ids = np.asarray(ids)
    w = np.ones(len(ids), np.float64) if weights is None else np.asarray(weights, np.float64)
    out = np.zeros((n_windows, num_bins), np.float64)
    ok = (win >= 0) & (win < n_windows) & (ids >= 0) & (ids < num_bins)
    np.add.at(out, (win[ok], ids[ok]), w[ok])
    return out


def ref_window_ip_overlap(src, dst, win, n_windows) -> np.ndarray:
    """Oracle for challenge.cross_window_ip_overlap.

    overlap[w] = |distinct IPs (src ∪ dst) active in window w AND in w-1|;
    overlap[0] = 0.
    """
    win = np.asarray(win)
    per_window = [
        set(np.concatenate([np.asarray(src)[win == w], np.asarray(dst)[win == w]]).tolist())
        for w in range(n_windows)
    ]
    out = np.zeros(n_windows, np.int64)
    for w in range(1, n_windows):
        out[w] = len(per_window[w] & per_window[w - 1])
    return out


def ref_anonymize_check(orig_src, orig_dst, anon_src, anon_dst) -> bool:
    """Anonymization invariant: the mapping IP -> id is a graph isomorphism.

    Checks (a) the map old->new is a well-defined bijection onto
    [0, n_unique_ips) and (b) the multiset of edges is preserved under it.
    """
    orig = np.concatenate([orig_src, orig_dst])
    anon = np.concatenate([anon_src, anon_dst])
    mapping: Dict[int, int] = {}
    for o, a in zip(orig.tolist(), anon.tolist()):
        if mapping.setdefault(o, a) != a:
            return False  # not a function
    vals = sorted(mapping.values())
    n = len(np.unique(orig))
    if vals != list(range(n)):
        return False  # not a bijection onto [0, n)
    remapped = [(mapping[s], mapping[d]) for s, d in zip(orig_src.tolist(), orig_dst.tolist())]
    return sorted(remapped) == sorted(zip(anon_src.tolist(), anon_dst.tolist()))
