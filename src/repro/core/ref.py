"""NumPy oracle for the challenge queries — the "single-core Pandas" role.

The paper benchmarks cuDF (GPU) against the identical code running on
single-core Pandas.  Pandas is not available in this environment, so this
module is the CPU reference: a straightforward, sequential NumPy
implementation of every Table III query with *dynamic* shapes.  It is the
ground truth for all correctness tests and the denominator of the Fig. 1
speedup benchmark.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

__all__ = [
    "ref_traffic_matrix",
    "ref_run_all_queries",
    "ref_anonymize_check",
]


def _weights(src: np.ndarray, n_packets: Optional[np.ndarray]) -> np.ndarray:
    return np.ones(len(src), np.int64) if n_packets is None else np.asarray(n_packets, np.int64)


def ref_traffic_matrix(src, dst, n_packets=None):
    """A_t as (src, dst, packets) arrays, lexicographically sorted."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    w = _weights(src, n_packets)
    order = np.lexsort((dst, src))
    s, d, w = src[order], dst[order], w[order]
    first = np.ones(len(s), bool)
    first[1:] = (s[1:] != s[:-1]) | (d[1:] != d[:-1])
    seg = np.cumsum(first) - 1
    packets = np.zeros(int(seg[-1]) + 1 if len(seg) else 0, np.int64)
    np.add.at(packets, seg, w)
    return s[first], d[first], packets


def ref_run_all_queries(src, dst, n_packets=None) -> Dict[str, int]:
    """All scalar challenge statistics (paper Table III), dynamically shaped."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    w = _weights(src, n_packets)
    ls, ld, lp = ref_traffic_matrix(src, dst, n_packets)

    def _maxcount(x) -> int:
        if len(x) == 0:
            return 0
        return int(np.unique(x, return_counts=True)[1].max())

    def _max_groupsum(keys, vals) -> int:
        if len(keys) == 0:
            return 0
        _, inv = np.unique(keys, return_inverse=True)
        sums = np.zeros(inv.max() + 1, np.int64)
        np.add.at(sums, inv, vals)
        return int(sums.max())

    return {
        "valid_packets": int(w.sum()),
        "unique_links": int(len(ls)),
        "max_link_packets": int(lp.max()) if len(lp) else 0,
        "n_unique_sources": int(len(np.unique(src))),
        "n_unique_destinations": int(len(np.unique(dst))),
        "n_unique_ips": int(len(np.unique(np.concatenate([src, dst])))),
        "max_source_packets": _max_groupsum(src, w),
        "max_source_fanout": _maxcount(ls),
        "max_destination_packets": _max_groupsum(dst, w),
        "max_destination_fanin": _maxcount(ld),
    }


def ref_anonymize_check(orig_src, orig_dst, anon_src, anon_dst) -> bool:
    """Anonymization invariant: the mapping IP -> id is a graph isomorphism.

    Checks (a) the map old->new is a well-defined bijection onto
    [0, n_unique_ips) and (b) the multiset of edges is preserved under it.
    """
    orig = np.concatenate([orig_src, orig_dst])
    anon = np.concatenate([anon_src, anon_dst])
    mapping: Dict[int, int] = {}
    for o, a in zip(orig.tolist(), anon.tolist()):
        if mapping.setdefault(o, a) != a:
            return False  # not a function
    vals = sorted(mapping.values())
    n = len(np.unique(orig))
    if vals != list(range(n)):
        return False  # not a bijection onto [0, n)
    remapped = [(mapping[s], mapping[d]) for s, d in zip(orig_src.tolist(), orig_dst.tolist())]
    return sorted(remapped) == sorted(zip(anon_src.tolist(), anon_dst.tolist()))
