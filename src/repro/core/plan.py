"""Sort-once query planning — the ``SortedEdges`` plan (DESIGN.md §2.3).

The engine's primitive is "stable sort + segment reduction" (§2), and the
analytics suite used to pay for it per *call site*: ``analyze()`` issued ~10
independent full-buffer sorts whose shared work XLA CSE could not dedupe
(different key orders, different operand sets).  This module restructures the
suite around the observation that **one lexicographic (src, dst) sort exposes
group structure at two granularities simultaneously**:

  * link level — adjacent-inequality on (src, dst) gives the distinct-link
    segmentation (the traffic matrix A_t);
  * leading-endpoint level — src groups are *prefixes* of the same lex
    order, so per-source aggregates, source fan-out and distinct sources
    derive from the identical sorted stream with ZERO additional sorts.

A ``SortedEdges`` value is that sorted stream plus both segmentations; the
derivation helpers below reproduce the exact ``GroupResult``/``UniqueResult``
buffers the naive per-query group-bys emit (bit-identical, including tail
padding), so consumers swap wholesale.  A mirrored dst-leading plan covers
the destination side; distinct IPs take one packed concat sort
(:func:`unique_concat`).  The sorts themselves are the packed single-operand
uint64 sorts of :mod:`repro.core.ops`.

The plan is a pytree: it crosses ``jit``/``shard_map`` boundaries and can be
built once per table and fanned out to every query.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

import jax
import jax.numpy as jnp

from .ops import (
    GroupResult,
    UniqueResult,
    _scatter_firsts,
    groupby_aggregate,
    multi_key_sort,
    segment_ids_from_sorted,
)
from .table import Table

__all__ = [
    "SortedEdges",
    "sorted_edges",
    "plan_for_table",
    "link_groups",
    "lead_groups",
    "lead_fanout",
    "unique_lead",
    "unique_concat",
    "count_hlo_sorts",
]


@dataclasses.dataclass(frozen=True)
class SortedEdges:
    """One packed lex sort of an edge table, with both segmentations.

    ``key0``/``key1`` are the sorted leading/trailing endpoint columns (live
    prefix of ``n_valid`` rows, tail undefined), ``w`` the per-row weights
    and ``row`` the original row index of each sorted row (the inverse
    permutation — consumers gather auxiliary columns such as window ids
    through it).

    ``seg``/``first``/``n_links`` segment the stream at (key0, key1)
    granularity, ``k0_seg``/``k0_first``/``n_k0`` at key0 granularity; both
    follow the :func:`repro.core.ops.segment_ids_from_sorted` conventions
    (padding rows carry segment id == capacity).
    """

    key0: jnp.ndarray
    key1: jnp.ndarray
    w: jnp.ndarray
    row: jnp.ndarray
    n_valid: jnp.ndarray  # scalar int32
    seg: jnp.ndarray
    first: jnp.ndarray
    n_links: jnp.ndarray  # scalar int32
    k0_seg: jnp.ndarray
    k0_first: jnp.ndarray
    n_k0: jnp.ndarray  # scalar int32

    @property
    def capacity(self) -> int:
        return self.key0.shape[0]

    def valid_rows(self) -> jnp.ndarray:
        return jnp.arange(self.capacity, dtype=jnp.int32) < self.n_valid

    def link_mask(self) -> jnp.ndarray:
        return jnp.arange(self.capacity, dtype=jnp.int32) < self.n_links

    def k0_mask(self) -> jnp.ndarray:
        return jnp.arange(self.capacity, dtype=jnp.int32) < self.n_k0

    def link_to_k0(self) -> jnp.ndarray:
        """(capacity + 1,) map link id -> key0 group id (capacity for pad)."""
        cap = self.capacity
        dst = jnp.where(self.first.astype(bool), self.seg, cap)
        return jnp.full((cap + 1,), cap, jnp.int32).at[dst].set(self.k0_seg)


jax.tree_util.register_dataclass(
    SortedEdges,
    data_fields=[f.name for f in dataclasses.fields(SortedEdges)],
    meta_fields=[],
)


def sorted_edges(
    key0: jnp.ndarray,
    key1: jnp.ndarray,
    weights: Optional[jnp.ndarray] = None,
    n_valid: Optional[jnp.ndarray] = None,
    valid_mask: Optional[jnp.ndarray] = None,
) -> SortedEdges:
    """Build the plan: ONE packed (key0, key1) sort, both segmentations.

    The second (key0-level) segmentation costs only an adjacent-inequality
    pass over the already-sorted stream — key0 groups are prefixes of the
    lex order.
    """
    key0 = jnp.asarray(key0)
    key1 = jnp.asarray(key1)
    cap = key0.shape[0]
    if weights is None:
        weights = jnp.ones((cap,), jnp.int32)
    if valid_mask is not None:
        n_valid = jnp.sum(valid_mask).astype(jnp.int32)
    else:
        n_valid = jnp.asarray(cap if n_valid is None else n_valid, jnp.int32)
    rows = jnp.arange(cap, dtype=jnp.int32)
    (s0, s1), (sw, srow) = multi_key_sort(
        [key0, key1], [weights, rows],
        n_valid=None if valid_mask is not None else n_valid,
        valid_mask=valid_mask,
    )
    seg, first, n_links = segment_ids_from_sorted([s0, s1], n_valid)
    k0_seg, k0_first, n_k0 = segment_ids_from_sorted([s0], n_valid)
    return SortedEdges(
        key0=s0, key1=s1, w=sw, row=srow, n_valid=n_valid,
        seg=seg, first=first, n_links=n_links,
        k0_seg=k0_seg, k0_first=k0_first, n_k0=n_k0,
    )


def plan_for_table(t: Table, lead: str = "src", trail: str = "dst") -> SortedEdges:
    """Plan over a packet table (weights = ``n_packets`` when present)."""
    w = t["n_packets"] if "n_packets" in t else None
    return sorted_edges(t[lead], t[trail], weights=w, n_valid=t.n_valid)


# -----------------------------------------------------------------------------
# derivations — each reproduces a naive group-by's buffers bit-for-bit
# -----------------------------------------------------------------------------

def _segsum(values: jnp.ndarray, seg: jnp.ndarray, cap: int) -> jnp.ndarray:
    return jax.ops.segment_sum(values, seg, num_segments=cap + 1)[:cap]


def link_groups(plan: SortedEdges, packets_name: str = "packets") -> GroupResult:
    """The traffic matrix A_t: ``groupby([key0, key1]).agg(count, sum(w))``."""
    cap = plan.capacity
    valid = plan.valid_rows()
    keys = (
        _scatter_firsts(plan.key0, plan.seg, plan.first, cap),
        _scatter_firsts(plan.key1, plan.seg, plan.first, cap),
    )
    aggs = {
        "count": _segsum(valid.astype(jnp.int32), plan.seg, cap),
        packets_name: _segsum(jnp.where(valid, plan.w, 0), plan.seg, cap),
    }
    return GroupResult(keys=keys, aggs=aggs, n_groups=plan.n_links)


def lead_groups(plan: SortedEdges, packets_name: str = "packets") -> GroupResult:
    """``groupby([key0]).agg(count, sum(w))`` — zero additional sorts."""
    cap = plan.capacity
    valid = plan.valid_rows()
    keys = (_scatter_firsts(plan.key0, plan.k0_seg, plan.k0_first, cap),)
    aggs = {
        "count": _segsum(valid.astype(jnp.int32), plan.k0_seg, cap),
        packets_name: _segsum(jnp.where(valid, plan.w, 0), plan.k0_seg, cap),
    }
    return GroupResult(keys=keys, aggs=aggs, n_groups=plan.n_k0)


def lead_fanout(plan: SortedEdges) -> GroupResult:
    """Distinct key1 per key0 over the link table (fan-out / fan-in).

    Naive form: ``groupby([links.keys[0]], None, n_valid=links.n_groups)``
    — a second full sort of the link buffer.  Here: links are counted into
    their key0 group by summing link-first flags, zero sorts.
    """
    cap = plan.capacity
    keys = (_scatter_firsts(plan.key0, plan.k0_seg, plan.k0_first, cap),)
    counts = _segsum(plan.first, plan.k0_seg, cap)
    return GroupResult(keys=keys, aggs={"count": counts}, n_groups=plan.n_k0)


def unique_lead(plan: SortedEdges) -> UniqueResult:
    """``unique(key0)`` with row multiplicities — zero additional sorts."""
    cap = plan.capacity
    valid = plan.valid_rows()
    return UniqueResult(
        values=_scatter_firsts(plan.key0, plan.k0_seg, plan.k0_first, cap),
        counts=_segsum(valid.astype(jnp.int32), plan.k0_seg, cap),
        weight_sums=None,
        n_unique=plan.n_k0,
    )


def unique_concat(
    a: jnp.ndarray,
    b: jnp.ndarray,
    n_valid: jnp.ndarray,
    positions: Optional[jnp.ndarray] = None,
    count_name: Optional[str] = "count",
) -> GroupResult:
    """Distinct values of ``concat(a, b)`` — ONE packed half-domain sort.

    ``a`` and ``b`` share a live prefix of ``n_valid`` rows; the two live
    blocks are compacted against each other with a gather so the (2*cap,)
    concat sorts with a plain prefix-validity packed key.  ``positions``
    (laid out like the concat: a-rows then b-rows) adds a ``first_pos`` min
    aggregate — the streaming dictionary's first-appearance rule.  This is
    both ``unique_ips`` (the anonymization domain) and the stream engine's
    batch-candidate extraction.
    """
    cap = a.shape[0]
    n_valid = jnp.asarray(n_valid, jnp.int32)
    both = jnp.concatenate([jnp.asarray(a), jnp.asarray(b)])
    idx = jnp.arange(2 * cap, dtype=jnp.int32)
    shifted = jnp.where(idx < n_valid, idx, idx - n_valid + cap)
    sel = jnp.where(idx < 2 * n_valid, shifted, 0)
    compact = both[sel]
    values = None
    if positions is not None:
        values = {"first_pos": (jnp.asarray(positions)[sel], "min")}
    return groupby_aggregate(
        [compact], values, n_valid=2 * n_valid, count_name=count_name
    )


# -----------------------------------------------------------------------------
# HLO sort accounting (the plan's budget, asserted in tests / benchmarks)
# -----------------------------------------------------------------------------

_SORT_DEF = re.compile(r"=\s[^=]*\bsort\(")
_DIM = re.compile(r"\[(\d+)")


def count_hlo_sorts(hlo_text: str, min_rows: int = 0) -> int:
    """Count sort ops in (compiled) HLO text with leading dim >= min_rows.

    Feed it ``jax.jit(fn).lower(*args).compile().as_text()`` — the
    post-optimization module, after CSE — so the count is what actually
    executes.  ``lax.top_k`` lowerings that expand to sorts are counted
    too: a sort is a sort.
    """
    n = 0
    for line in hlo_text.splitlines():
        if _SORT_DEF.search(line):
            dims = [int(d) for d in _DIM.findall(line)]
            if dims and max(dims) >= min_rows:
                n += 1
    return n
