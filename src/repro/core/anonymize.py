"""IP-address anonymization — paper §IV "IP Address Anonymization".

The paper's recipe, verbatim in data-science ops:

  1. ``unique`` over the union of src and dst columns  -> N distinct IPs,
  2. generate ``iota(N)`` and ``shuffle`` it  -> random permutation,
  3. ``gather`` new ids for every row.

We provide the stochastic variant (``cupy.random.shuffle`` analogue via
``jax.random``) and the deterministic HashGraph-style variant the paper cites
as future work (Green et al. [22, 23]) — both over static-shape buffers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .ops import factorize, hash_permutation, random_permutation
from .queries import unique_ips
from .table import Table

__all__ = ["AnonymizationResult", "anonymize"]


@dataclasses.dataclass(frozen=True)
class AnonymizationResult:
    table: Table           # same schema, src/dst replaced by anonymized ids
    ip_values: jnp.ndarray  # sorted distinct original IPs (tail-padded)
    new_ids: jnp.ndarray    # new_ids[rank] = anonymized id of ip_values[rank]
    n_ips: jnp.ndarray      # scalar int32


jax.tree_util.register_pytree_node(
    AnonymizationResult,
    lambda a: ((a.table, a.ip_values, a.new_ids, a.n_ips), None),
    lambda _, ch: AnonymizationResult(*ch),
)


def anonymize(
    t: Table,
    key: Optional[jax.Array] = None,
    *,
    method: str = "shuffle",
    rounds: int = 1,
) -> AnonymizationResult:
    """Anonymize ``src``/``dst`` of a packet table.

    Args:
      t: packet table with ``src`` and ``dst`` columns.
      key: PRNG key (required for ``method='shuffle'``).
      method: ``'shuffle'`` (paper's cupy.random.shuffle analogue) or
        ``'hash'`` (deterministic HashGraph-style permutation, Green et al.).
      rounds: extra shuffle rounds — the paper notes one or two extra
        iterations further decorrelate the permutation at negligible cost.
    """
    ips = unique_ips(t)
    cap = ips.values.shape[0]
    n = ips.n_unique
    if method == "shuffle":
        if key is None:
            raise ValueError("method='shuffle' requires a PRNG key")
        keys = jax.random.split(key, rounds)
        perm = random_permutation(keys[0], cap, n)
        for k in keys[1:]:
            # composing uniform permutations == shuffling again (paper §IV)
            perm = perm[random_permutation(k, cap, n)]
    elif method == "hash":
        perm = hash_permutation(cap, n)
        for r in range(1, rounds):
            perm = perm[hash_permutation(cap, n, salt=0x9E3779B9 + r)]
    else:
        raise ValueError(f"unknown method {method!r}")

    src_rank = factorize(t["src"], ips.values)
    dst_rank = factorize(t["dst"], ips.values)
    anon = t.with_columns(src=perm[src_rank], dst=perm[dst_rank])
    return AnonymizationResult(table=anon, ip_values=ips.values, new_ids=perm, n_ips=n)
