"""repro.core — the paper's primary contribution as a composable JAX library.

"jaxdf": a static-shape columnar table + the relational ETL ops the paper
uses to express the Anonymized Network Sensing Graph Challenge (unique,
value_counts, groupby-aggregate, drop_duplicates), the 14 challenge queries,
and the IP-anonymization pipeline.  ``ref.py`` is the sequential NumPy oracle
standing in for single-core Pandas.
"""
from .table import Table
from .ops import (
    GroupResult,
    UniqueResult,
    drop_duplicates,
    factorize,
    groupby_aggregate,
    hash_permutation,
    isin,
    mix32,
    multi_key_sort,
    random_permutation,
    segment_ids_from_sorted,
    semi_join,
    top_k,
    unique,
    value_counts,
)
from .queries import QueryResults, TopLinks, run_all_queries, top_links, traffic_matrix
from .anonymize import AnonymizationResult, anonymize
from .temporal import window_ids, windowed_queries

__all__ = [
    "Table",
    "GroupResult",
    "UniqueResult",
    "drop_duplicates",
    "factorize",
    "groupby_aggregate",
    "hash_permutation",
    "isin",
    "mix32",
    "multi_key_sort",
    "random_permutation",
    "segment_ids_from_sorted",
    "semi_join",
    "top_k",
    "unique",
    "value_counts",
    "QueryResults",
    "TopLinks",
    "run_all_queries",
    "top_links",
    "traffic_matrix",
    "AnonymizationResult",
    "anonymize",
    "window_ids",
    "windowed_queries",
]
