"""repro — Anonymized Network Sensing Graph Challenge as data-science ETL,
reproduced and scaled out in JAX/TPU.  See README.md / DESIGN.md."""

__version__ = "1.0.0"
