"""Example: SchNet energy regression on batched synthetic molecules.

Exercises the GNN stack end-to-end: batched small graphs (the ``molecule``
shape regime), segment-op message passing, and the shared training loop.
The planted target is the pairwise Lennard-Jones-like energy of each random
conformation, so the loss has real geometric signal.

    PYTHONPATH=src python examples/gnn_molecules.py --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import gnn as G
from repro.train import AdamWConfig, Trainer


def make_batch(rng, batch=32, n_atoms=12, n_types=6):
    """Random conformations + planted pairwise energy target."""
    N = batch * n_atoms
    types = rng.integers(1, n_types, (N, 1)).astype(np.int32)
    pos = rng.standard_normal((N, 3)).astype(np.float32) * 1.5
    gid = np.repeat(np.arange(batch, dtype=np.int32), n_atoms)
    # fully-connected intra-molecule edges (directed both ways)
    offs = np.arange(batch)[:, None, None] * n_atoms
    ij = np.stack(np.meshgrid(np.arange(n_atoms), np.arange(n_atoms)), -1)
    ij = ij[ij[..., 0] != ij[..., 1]]  # (n_atoms*(n_atoms-1), 2)
    senders = (offs + ij[None, :, 0]).reshape(-1).astype(np.int32)
    receivers = (offs + ij[None, :, 1]).reshape(-1).astype(np.int32)
    d = np.linalg.norm(pos[senders] - pos[receivers], axis=-1)
    e_pair = 4.0 * ((0.8 / d) ** 12 - (0.8 / d) ** 6).clip(-5, 5)
    target = np.zeros(batch, np.float32)
    np.add.at(target, gid[receivers], e_pair.astype(np.float32) / 2)
    return {
        "nodes": types, "positions": pos, "senders": senders,
        "receivers": receivers, "graph_ids": gid,
        "target": target[:, None] / 10.0,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    args = ap.parse_args()

    cfg = G.SchNetConfig(n_interactions=3, d_hidden=64, n_rbf=50, cutoff=6.0)
    params = G.schnet_init(jax.random.key(0), cfg)
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"SchNet: {n / 1e3:.0f}k params, batch={args.batch} molecules")

    def loss_fn(p, b):
        g = G.Graph(nodes=b["nodes"], senders=b["senders"],
                    receivers=b["receivers"], positions=b["positions"],
                    graph_ids=b["graph_ids"], n_graphs=args.batch)
        pred = G.schnet_apply(p, cfg, g)
        return jnp.mean((pred - b["target"]) ** 2), {}

    def batches():
        step = 0
        while True:
            rng = np.random.default_rng((42, step))
            yield make_batch(rng, batch=args.batch)
            step += 1

    trainer = Trainer(loss_fn, AdamWConfig(lr=2e-3, warmup_steps=20,
                                           total_steps=args.steps))
    state = trainer.init_state(params)
    t0 = time.time()
    state, hist = trainer.run(state, batches(), args.steps, log_every=40)
    print(f"done in {time.time() - t0:.0f}s — final MSE {hist['loss']:.5f}")
    assert hist["loss"] < 0.5


if __name__ == "__main__":
    main()
