"""Quickstart: the full Anonymized Network Sensing pipeline in ~40 lines.

Generates RMAT traffic (the challenge's hypersparse regime), stores it
columnar (plq), anonymizes the IPs, and runs all 14 challenge queries —
validating against the sequential NumPy oracle.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Table, anonymize, run_all_queries
from repro.core.ref import ref_anonymize_check, ref_run_all_queries
from repro.data.plq import read_plq, write_plq
from repro.data.rmat import synthetic_packets


def main(n_packets: int = 1 << 18) -> None:
    # 1. capture -> columnar store (paper: PCAP -> Parquet)
    cols = synthetic_packets(n_packets, scale=16, seed=0)
    path = os.path.join(tempfile.mkdtemp(), "packets.plq")
    write_plq(path, cols)
    cols = read_plq(path, ["src", "dst"])
    print(f"loaded {n_packets:,} packets from {path}")

    # 2. build the packet table
    table = Table.from_dict({
        "src": jnp.asarray(cols["src"].astype(np.int32)),
        "dst": jnp.asarray(cols["dst"].astype(np.int32)),
    })

    # 3. anonymize (unique -> shuffle -> gather, paper §IV)
    anon = jax.jit(lambda t, k: anonymize(t, k))(table, jax.random.key(0))
    ok = ref_anonymize_check(
        cols["src"].astype(np.int64), cols["dst"].astype(np.int64),
        np.asarray(anon.table["src"]), np.asarray(anon.table["dst"]))
    print(f"anonymized {int(anon.n_ips):,} unique IPs (isomorphism check: {ok})")

    # 4. the 14 challenge queries (paper Table III)
    res = jax.jit(run_all_queries)(anon.table)
    ref = ref_run_all_queries(cols["src"], cols["dst"])
    print(f"{'query':28s}{'jaxdf':>12s}{'numpy oracle':>14s}")
    for k, v in ref.items():
        got = int(getattr(res, k))
        mark = "" if got == v else "  <-- MISMATCH"
        print(f"{k:28s}{got:12,}{v:14,}{mark}")
        assert got == v, k
    print("all queries match the oracle ✓")


if __name__ == "__main__":
    main()
