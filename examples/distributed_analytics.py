"""Example: the paper's pipeline at pod scale (8 simulated devices).

Shards a packet table over 8 host devices, runs the hash-partition
all_to_all distributed queries (dist/relational.py), and checks exactness
vs the single-device path — the "2^30 edges won't fit one 16 GB chip"
scenario from DESIGN.md §5.

NOTE: re-execs itself with XLA_FLAGS to force 8 host devices.

    PYTHONPATH=src python examples/distributed_analytics.py
"""
import os
import sys

if "XLA_FLAGS" not in os.environ or "host_platform_device_count" not in os.environ["XLA_FLAGS"]:
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8").strip()
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.core.ref import ref_run_all_queries
from repro.core.table import Table
from repro.dist import distributed_queries
from repro.data.rmat import synthetic_packets


def main(n: int = 1 << 20) -> None:
    print(f"devices: {len(jax.devices())}")
    cols = synthetic_packets(n, scale=20, seed=0)
    src = cols["src"].astype(np.int32)
    dst = cols["dst"].astype(np.int32)

    mesh = jax.make_mesh((8,), ("rows",))
    fn = jax.jit(shard_map(
        lambda s, d: distributed_queries(
            Table.from_dict({"src": s, "dst": d}), "rows"),
        mesh=mesh, in_specs=(P("rows"), P("rows")), out_specs=P(),
    ))
    out = fn(src, dst)
    ref = ref_run_all_queries(src, dst)
    print(f"{'query':28s}{'8-shard':>12s}{'oracle':>12s}")
    for k, v in ref.items():
        got = int(out[k])
        print(f"{k:28s}{got:12,}{v:12,}")
        assert got == v, k
    assert int(out["overflow"]) == 0
    print(f"overflow=0; all {len(ref)} distributed queries exact ✓")


if __name__ == "__main__":
    main()
