"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the real production substrate — deterministic prefetching pipeline,
AdamW + WSD schedule, atomic checkpointing with resume, straggler watchdog —
on a granite-style GQA architecture scaled to ~100M params for CPU.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.data.pipeline import Prefetcher, lm_batches
from repro.models.transformer import TransformerConfig, init_params, loss_fn
from repro.train import AdamWConfig, Trainer


def make_config() -> TransformerConfig:
    # ~100M params: 12L × d512 (GQA 8/2) + 32k vocab
    return TransformerConfig(
        name="granite-100m", n_layers=12, d_model=512, n_heads=8,
        n_kv_heads=2, d_ff=1536, vocab=32_000, dtype=jnp.float32,
        remat=False,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    ckpt = args.ckpt_dir or os.path.join(tempfile.mkdtemp(), "ckpt")

    cfg = make_config()
    params = init_params(jax.random.key(0), cfg)
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params={n / 1e6:.1f}M  "
          f"tokens/step={args.batch * args.seq:,}")

    trainer = Trainer(
        lambda p, b: loss_fn(p, cfg, b["tokens"], b["labels"]),
        AdamWConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps,
                    schedule="wsd", decay_fraction=0.15),
        ckpt_dir=ckpt, ckpt_every=100,
    )
    state = trainer.init_state(params)
    batches = Prefetcher(lm_batches(args.batch, args.seq, cfg.vocab, seed=0))
    t0 = time.time()
    state, hist = trainer.run(state, batches, args.steps, log_every=25)
    dt = time.time() - t0
    print(f"done in {dt:.0f}s — {args.steps * args.batch * args.seq / dt:,.0f} tok/s, "
          f"final loss {hist['loss']:.4f}, checkpoints in {ckpt}")
    assert hist["loss"] < 7.0, "loss did not move"


if __name__ == "__main__":
    main()
